"""Pallas TPU paged attention: decode (one query token per request slot) and
chunked prefill (a fixed-width chunk of query tokens per slot), with K/V
gathered from fixed-size pages through per-request block tables.

This is the serving twin of kernels/flash_attention.py: same online-softmax
recurrence, but the KV sequence is PHYSICALLY SCATTERED across a page pool
(NP, BS, KV, D) and addressed logically by ``block_tables (R, MB)``.  The
tables (plus per-request positions) ride in as SCALAR-PREFETCH operands
(``pltpu.PrefetchScalarGridSpec``), so each grid step's K/V page index is
known before the body runs and the DMA fetches exactly one page per step —
no dense gather of the whole context ever materializes.

Grid: (R, KV, MB) with the block dim innermost and "arbitrary" (sequential)
so the softmax state lives in VMEM scratch across page iterations.  GQA is
folded like the flash kernel: the G = H/KV query heads sharing a kv head form
the q row dim of a (G, D) tile, so K/V stay at kv-head width.

Masking is positional only: key j is valid iff ``j <= positions[r]`` (and
``j > positions[r] - window`` for sliding-window layers).  Pages past the
context, unallocated table entries (pointing anywhere) and the trash page are
all invalid by position, so garbage page contents never reach the softmax.
Fully-masked pages self-heal exactly as in the flash kernel: their p=1 rows
are wiped by corr=0 once a finite-max page arrives, and for causal decode
page 0 is always valid.

VMEM per program: q (G, D) + k/v (BS, D) + acc (G, D) f32 + m/l (G,)
≈ a few KiB for typical (G ≤ 8, BS ≤ 64, D ≤ 256) — paging keeps the decode
working set independent of context length.  Validated on CPU with
interpret=True against ref.jnp_paged_attention; the TPU is the TARGET.

CHUNKED PREFILL (``pallas_paged_chunk_attention``) is the same kernel shape
with C query tokens per slot instead of one: query row c of slot r sits at
absolute position ``positions[r] + c`` and key j is valid iff
``j <= positions[r] + c``.  A RAGGED last chunk needs no extra machinery —
tokens past the slot's valid length were scattered to the trash page by the
caller, so their pages hold nothing, and their query rows compute garbage
that the caller discards; the per-row positional mask is what keeps the
garbage out of every VALID row.  One fixed (C) program therefore serves any
prompt-length mix: this is what retires the per-length prefill compile zoo.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tpu_compat import CompilerParams

NEG_INF = -1e30


def _kernel(
    tables_ref, pos_ref,               # scalar-prefetch: (R, MB), (R,)
    q_ref, k_ref, v_ref,               # VMEM tiles
    o_ref,                             # (1, 1, G, D) output tile (revisited)
    acc_ref, m_ref, l_ref,             # scratch: f32 softmax state
    *,
    mode: str,
    window: int,
    page_size: int,
    scale: float,
):
    r = pl.program_id(0)
    bi = pl.program_id(2)
    nb = pl.num_programs(2)

    @pl.when(bi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale        # (G, D)
    k = k_ref[0, :, 0].astype(jnp.float32)             # (BS, D)
    v = v_ref[0, :, 0].astype(jnp.float32)

    s = q @ k.T                                        # (G, BS)

    pos = pos_ref[r]
    kv_pos = bi * page_size + jax.lax.broadcasted_iota(
        jnp.int32, s.shape, 1
    )
    valid = kv_pos <= pos
    if mode == "local":
        valid &= kv_pos > pos - window
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + p @ v
    m_ref[...] = m_new

    @pl.when(bi == nb - 1)
    def _finalize():
        out = acc_ref[...] / jnp.maximum(l_ref[...][:, None], 1e-30)
        o_ref[0, 0] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("mode", "window", "interpret")
)
def pallas_paged_attention(
    q: jax.Array,             # (R, H, D) — one decode token per request slot
    k_pages: jax.Array,       # (NP, BS, KV, D)
    v_pages: jax.Array,       # (NP, BS, KV, D)
    block_tables: jax.Array,  # (R, MB) int32
    positions: jax.Array,     # (R,) int32
    *,
    mode: str = "causal",
    window: int = 0,
    interpret: bool = True,
) -> jax.Array:
    """Paged decode attention at model layout — requires H % KV == 0 (the ops
    wrapper routes non-divisible head counts to the jnp twin)."""
    r, h, d = q.shape
    np_, bs, kvh, _ = k_pages.shape
    mb = block_tables.shape[1]
    if h % kvh:
        raise ValueError(
            f"pallas paged attention needs H % KV == 0, got H={h} KV={kvh}"
        )
    g = h // kvh
    scale = 1.0 / math.sqrt(d)
    qg = q.reshape(r, kvh, g, d)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(r, kvh, mb),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda ri, hi, bi, tbl, pos: (ri, hi, 0, 0)),
            pl.BlockSpec(
                (1, bs, 1, d), lambda ri, hi, bi, tbl, pos: (tbl[ri, bi], 0, hi, 0)
            ),
            pl.BlockSpec(
                (1, bs, 1, d), lambda ri, hi, bi, tbl, pos: (tbl[ri, bi], 0, hi, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, g, d), lambda ri, hi, bi, tbl, pos: (ri, hi, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((g, d), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(
            _kernel, mode=mode, window=window, page_size=bs, scale=scale
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(qg.shape, q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), positions.astype(jnp.int32), qg, k_pages, v_pages)
    return out.reshape(r, h, d)


def _chunk_kernel(
    tables_ref, pos_ref,               # scalar-prefetch: (R, MB), (R,)
    q_ref, k_ref, v_ref,               # VMEM tiles
    o_ref,                             # (1, 1, C*G, D) output tile (revisited)
    acc_ref, m_ref, l_ref,             # scratch: f32 softmax state
    *,
    mode: str,
    window: int,
    page_size: int,
    scale: float,
    group: int,
):
    r = pl.program_id(0)
    bi = pl.program_id(2)
    nb = pl.num_programs(2)

    @pl.when(bi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale        # (C*G, D)
    k = k_ref[0, :, 0].astype(jnp.float32)             # (BS, D)
    v = v_ref[0, :, 0].astype(jnp.float32)

    s = q @ k.T                                        # (C*G, BS)

    # Row c*G + g of the folded q tile is chunk token c: its absolute query
    # position is the slot base plus the within-chunk offset.
    base = pos_ref[r]
    q_pos = base + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // group
    kv_pos = bi * page_size + jax.lax.broadcasted_iota(
        jnp.int32, s.shape, 1
    )
    valid = kv_pos <= q_pos
    if mode == "local":
        valid &= kv_pos > q_pos - window
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + p @ v
    m_ref[...] = m_new

    @pl.when(bi == nb - 1)
    def _finalize():
        out = acc_ref[...] / jnp.maximum(l_ref[...][:, None], 1e-30)
        o_ref[0, 0] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("mode", "window", "interpret")
)
def pallas_paged_chunk_attention(
    q: jax.Array,             # (R, C, H, D) — one prefill chunk per slot
    k_pages: jax.Array,       # (NP, BS, KV, D)
    v_pages: jax.Array,       # (NP, BS, KV, D)
    block_tables: jax.Array,  # (R, MB) int32
    positions: jax.Array,     # (R,) int32 — base position of chunk token 0
    *,
    mode: str = "causal",
    window: int = 0,
    interpret: bool = True,
) -> jax.Array:
    """Chunked paged prefill attention — requires H % KV == 0 (the ops wrapper
    routes non-divisible head counts to the jnp twin).  Chunk token c of slot
    r queries at position ``positions[r] + c``; rows past the slot's ragged
    length produce garbage that the caller discards."""
    r, c, h, d = q.shape
    np_, bs, kvh, _ = k_pages.shape
    mb = block_tables.shape[1]
    if h % kvh:
        raise ValueError(
            f"pallas paged attention needs H % KV == 0, got H={h} KV={kvh}"
        )
    g = h // kvh
    scale = 1.0 / math.sqrt(d)
    # Fold chunk tokens AND grouped query heads into one q row dim so K/V
    # tiles stay at kv-head width: row index = c * g + gi.
    qg = q.reshape(r, c, kvh, g, d).transpose(0, 2, 1, 3, 4).reshape(r, kvh, c * g, d)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(r, kvh, mb),
        in_specs=[
            pl.BlockSpec(
                (1, 1, c * g, d), lambda ri, hi, bi, tbl, pos: (ri, hi, 0, 0)
            ),
            pl.BlockSpec(
                (1, bs, 1, d), lambda ri, hi, bi, tbl, pos: (tbl[ri, bi], 0, hi, 0)
            ),
            pl.BlockSpec(
                (1, bs, 1, d), lambda ri, hi, bi, tbl, pos: (tbl[ri, bi], 0, hi, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, c * g, d), lambda ri, hi, bi, tbl, pos: (ri, hi, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((c * g, d), jnp.float32),
            pltpu.VMEM((c * g,), jnp.float32),
            pltpu.VMEM((c * g,), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(
            _chunk_kernel,
            mode=mode,
            window=window,
            page_size=bs,
            scale=scale,
            group=g,
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(qg.shape, q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), positions.astype(jnp.int32), qg, k_pages, v_pages)
    return out.reshape(r, kvh, c, g, d).transpose(0, 2, 1, 3, 4).reshape(r, c, h, d)
