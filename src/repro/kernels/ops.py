"""Jit'd public wrappers around the Pallas kernels.

``interpret=True`` by default: this box is CPU-only and the TPU is the
TARGET; on a real TPU pass interpret=False (kernels use MXU-aligned 128
blocks and explicit VMEM BlockSpecs — see each kernel's module docstring).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.noloco_update import noloco_update_flat
from repro.kernels.ssd_scan import ssd_chunk_kernel

__all__ = ["flash_attention", "noloco_update_pytree", "ssd_chunk"]


def flash_attention(
    q: jax.Array,   # (B, Sq, H, D)
    k: jax.Array,   # (B, Sk, KV, D)
    v: jax.Array,   # (B, Sk, KV, D)
    *,
    mode: str = "causal",
    window: int = 0,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """GQA flash attention: kv heads are expanded to q heads (gather), batch
    and heads flattened into the kernel's grid dim."""
    b, sq, h, d = q.shape
    kvh = k.shape[2]
    head_map = (jnp.arange(h) * kvh) // h
    k = jnp.take(k, head_map, axis=2)
    v = jnp.take(v, head_map, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, -1, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, -1, d)
    out = flash_attention_bhsd(
        qf, kf, vf, mode=mode, window=window,
        block_q=block_q, block_kv=block_kv, interpret=interpret,
    )
    return out.reshape(b, h, sq, d).transpose(0, 2, 1, 3)


def noloco_update_pytree(
    theta, phi, delta_mom, theta_partner, phi_partner,
    *, alpha: float, beta: float, gamma: float, interpret: bool = True,
):
    """Fused Eq. 1–3 over whole pytrees: leaves are raveled, concatenated
    conceptually per-leaf (each leaf gets its own kernel launch — leaves are
    large enough that launch overhead is negligible)."""
    flat, treedef = jax.tree.flatten(theta)
    phis = jax.tree.leaves(phi)
    dms = jax.tree.leaves(delta_mom)
    tps = jax.tree.leaves(theta_partner)
    pps = jax.tree.leaves(phi_partner)
    new_phi, new_delta = [], []
    for t, p, d, tp_, pp_ in zip(flat, phis, dms, tps, pps):
        shape = p.shape
        np_, nd_ = noloco_update_flat(
            t.ravel(), p.ravel(), d.ravel(), tp_.ravel(), pp_.ravel(),
            alpha=alpha, beta=beta, gamma=gamma, interpret=interpret,
        )
        new_phi.append(np_.reshape(shape))
        new_delta.append(nd_.reshape(shape))
    return (
        jax.tree.unflatten(treedef, new_phi),
        jax.tree.unflatten(treedef, new_delta),
    )


def ssd_chunk(x, dt, a, b_mat, c_mat, *, chunk: int, interpret: bool = True):
    """Full SSD via the Pallas intra-chunk kernel + jnp inter-chunk scan.
    Matches ref.reference_ssd. x (B,S,H,P), dt (B,S,H), a (H,), B/C (B,S,N)."""
    import math

    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    q = min(chunk, s)
    nc = math.ceil(s / q)
    pad = nc * q - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0)))

    xc = x.reshape(bsz, nc, q, h, p)
    dtc = dt.reshape(bsz, nc, q, h)
    bc = b_mat.reshape(bsz, nc, q, n)
    cc = c_mat.reshape(bsz, nc, q, n)

    y_diag, states = ssd_chunk_kernel(xc, dtc, a, bc, cc, interpret=interpret)

    # inter-chunk state recurrence (cheap, sequential)
    da = dtc.astype(jnp.float32) * a[None, None, None, :]
    chunk_decay = jnp.exp(jnp.sum(da, axis=2))            # (B,nc,H)
    cums = jnp.cumsum(da, axis=2)

    def body(prev, inp):
        st, dec = inp
        new = prev * dec[:, :, None, None] + st
        return new, prev

    final, prev_states = jax.lax.scan(
        body, jnp.zeros((bsz, h, n, p), jnp.float32),
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)     # (B,nc,H,N,P)

    y_off = jnp.einsum(
        "bcin,bchnp,bcih->bcihp",
        cc.astype(jnp.float32), prev_states, jnp.exp(cums),
    )
    y = (y_diag.astype(jnp.float32) + y_off).reshape(bsz, nc * q, h, p)[:, :s]
    final = final.transpose(0, 1, 3, 2)                    # (B,H,P,N)
    return y.astype(x.dtype), final
