"""Public, differentiable wrappers around the dispatched kernels.

Each op here is the PRODUCTION entry its consumers call (models, core/outer,
comm): it resolves a :class:`~repro.kernels.dispatch.KernelConfig`, picks the
Pallas kernel or the jnp twin from the dispatch table, and — for the ops that
sit inside the training forward — wraps the choice in ``jax.custom_vjp``
whose backward is the vjp of the jnp twin.  Pallas kernels have no autodiff
rules; the twin computes the SAME function with online-softmax / chunked
recompute, so gradients are exact and memory-bounded regardless of which
implementation ran the forward.

``interpret`` resolution: True off-TPU, False on TPU (overridable via
``KernelConfig.interpret``) — this box is CPU-only and the TPU is the TARGET.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.dispatch import KernelConfig, default_config, dispatch

__all__ = [
    "flash_attention",
    "ssd_chunk",
    "rglru_scan",
    "noloco_update_pytree",
    "int8_quantize",
    "int8_dequantize",
    "paged_attention",
    "paged_chunk_attention",
    "rglru_decode",
    "ssd_decode",
]


def _resolve(config: KernelConfig | None) -> tuple[str, bool]:
    cfg = config if config is not None else default_config()
    return cfg.resolved_impl(), cfg.resolved_interpret()


# ---------------------------------------------------------------------------
# Flash attention (differentiable; jnp online-softmax backward)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _attention_op(mode, window, block_q, block_kv, impl, interpret, unroll):
    if impl == "pallas":
        fwd_impl = functools.partial(
            dispatch("flash_attention", KernelConfig("pallas", interpret)),
            mode=mode, window=window, block_q=block_q, block_kv=block_kv,
        )
    else:
        fwd_impl = functools.partial(
            dispatch("flash_attention", KernelConfig("jnp")),
            mode=mode, window=window, unroll=unroll,
        )
    jnp_twin = functools.partial(
        ref.jnp_flash_attention, mode=mode, window=window, unroll=unroll
    )

    @jax.custom_vjp
    def op(q, k, v):
        return fwd_impl(q, k, v)

    def fwd(q, k, v):
        return fwd_impl(q, k, v), (q, k, v)

    def bwd(res, g):
        q, k, v = res
        _, vjp = jax.vjp(jnp_twin, q, k, v)
        return vjp(g)

    op.defvjp(fwd, bwd)
    return op


def flash_attention(
    q: jax.Array,   # (B, Sq, H, D)
    k: jax.Array,   # (B, Sk, KV, D)
    v: jax.Array,   # (B, Sk, KV, D)
    *,
    mode: str = "causal",
    window: int = 0,
    block_q: int = 128,
    block_kv: int = 128,
    unroll: bool = False,
    config: KernelConfig | None = None,
) -> jax.Array:
    """GQA flash attention over canonical (arange) positions.

    K/V stay at kv-head width end to end: the Pallas path folds the G = H/KV
    query heads per kv head into the q row dimension, the jnp path groups the
    einsums — neither materializes K/V expanded to all query heads.
    ``unroll`` unrolls the jnp path's KV scan (dry-run cost analysis)."""
    impl, interpret = _resolve(config)
    return _attention_op(mode, window, block_q, block_kv, impl, interpret, unroll)(
        q, k, v
    )


# ---------------------------------------------------------------------------
# SSD (Mamba-2): dispatched intra-chunk quadratic form + jnp inter-chunk scan
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _ssd_intra_op(impl, interpret):
    if impl == "pallas":
        fwd_impl = dispatch("ssd_chunk", KernelConfig("pallas", interpret))
    else:
        fwd_impl = dispatch("ssd_chunk", KernelConfig("jnp"))
    jnp_twin = ref.jnp_ssd_chunk_intra

    @jax.custom_vjp
    def op(xc, dtc, a, bc, cc):
        return fwd_impl(xc, dtc, a, bc, cc)

    def fwd(xc, dtc, a, bc, cc):
        return fwd_impl(xc, dtc, a, bc, cc), (xc, dtc, a, bc, cc)

    def bwd(res, g):
        _, vjp = jax.vjp(jnp_twin, *res)
        return vjp(g)

    op.defvjp(fwd, bwd)
    return op


def ssd_chunk(
    x: jax.Array,      # (B, S, H, P)
    dt: jax.Array,     # (B, S, H)
    a: jax.Array,      # (H,)
    b_mat: jax.Array,  # (B, S, N)
    c_mat: jax.Array,  # (B, S, N)
    *,
    chunk: int,
    initial_state: jax.Array | None = None,  # (B, H, P, N)
    unroll: bool = False,
    config: KernelConfig | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Full chunked SSD: dispatched intra-chunk O(Q²) form + cheap sequential
    inter-chunk state recurrence in jnp.  Matches ref.reference_ssd.
    Returns (y (B,S,H,P) in x.dtype, final_state (B,H,P,N) f32)."""
    impl, interpret = _resolve(config)
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    q = min(chunk, s)
    nc = math.ceil(s / q)
    pad = nc * q - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0)))

    xc = x.reshape(bsz, nc, q, h, p)
    dtc = dt.reshape(bsz, nc, q, h)
    bc = b_mat.reshape(bsz, nc, q, n)
    cc = c_mat.reshape(bsz, nc, q, n)

    y_diag, states = _ssd_intra_op(impl, interpret)(xc, dtc, a, bc, cc)

    # inter-chunk state recurrence (cheap, sequential, differentiates normally)
    da = dtc.astype(jnp.float32) * a[None, None, None, :]
    chunk_decay = jnp.exp(jnp.sum(da, axis=2))            # (B,nc,H)
    cums = jnp.cumsum(da, axis=2)

    def body(prev, inp):
        st, dec = inp
        new = prev * dec[:, :, None, None] + st
        return new, prev  # emit the state ENTERING this chunk

    s0 = (
        jnp.zeros((bsz, h, n, p), jnp.float32)
        if initial_state is None
        # caches carry (B,H,P,N); the kernel's state layout is (B,H,N,P)
        else initial_state.astype(jnp.float32).transpose(0, 1, 3, 2)
    )
    final, prev_states = jax.lax.scan(
        body,
        s0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
        unroll=unroll,
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)     # (B,nc,H,N,P)

    y_off = jnp.einsum(
        "bcin,bchnp,bcih->bcihp",
        cc.astype(jnp.float32), prev_states, jnp.exp(cums),
    )
    y = (y_diag.astype(jnp.float32) + y_off).reshape(bsz, nc * q, h, p)[:, :s]
    final = final.transpose(0, 1, 3, 2)                    # (B,H,P,N)
    return y.astype(x.dtype), final


# ---------------------------------------------------------------------------
# RG-LRU linear recurrence (differentiable; associative-scan backward)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _rglru_op(impl, interpret):
    if impl == "pallas":
        fwd_impl = dispatch("rglru_scan", KernelConfig("pallas", interpret))
    else:
        fwd_impl = dispatch("rglru_scan", KernelConfig("jnp"))
    jnp_twin = ref.jnp_rglru_scan

    @jax.custom_vjp
    def op(a, b):
        return fwd_impl(a, b)

    def fwd(a, b):
        return fwd_impl(a, b), (a, b)

    def bwd(res, g):
        _, vjp = jax.vjp(jnp_twin, *res)
        return vjp(g)

    op.defvjp(fwd, bwd)
    return op


def rglru_scan(
    a: jax.Array,   # (B, S, W) f32 per-step decay
    b: jax.Array,   # (B, S, W) f32 per-step input
    *,
    config: KernelConfig | None = None,
) -> jax.Array:
    """Inclusive scan of h_t = a_t·h_{t-1} + b_t over axis 1 (zero h_0)."""
    impl, interpret = _resolve(config)
    return _rglru_op(impl, interpret)(a, b)


# ---------------------------------------------------------------------------
# Fused NoLoCo outer update (Eqs. 2–3 over group statistics)
# ---------------------------------------------------------------------------


def noloco_update_pytree(
    phi,
    delta_mom,
    mean_delta,
    mean_phi,
    *,
    alpha: float,
    beta: float,
    gamma: float,
    config: KernelConfig | None = None,
):
    """Fused Eqs. 2–3 over whole pytrees; returns (phi_next, delta_next).

    The update is elementwise, so leaves are raveled per-leaf into the 1-D
    kernel (leaves are large enough that launch overhead is negligible;
    stacked leaves with a leading replica axis ravel correctly too).  Not
    differentiated — the outer step sits outside jax.grad."""
    impl, interpret = _resolve(config)
    flat_phi, treedef = jax.tree.flatten(phi)
    dms = jax.tree.leaves(delta_mom)
    mds = jax.tree.leaves(mean_delta)
    mps = jax.tree.leaves(mean_phi)
    if impl == "pallas":
        fn = dispatch("noloco_update", KernelConfig("pallas", interpret))
        new_phi, new_delta = [], []
        for p, d, md, mp in zip(flat_phi, dms, mds, mps):
            np_, nd_ = fn(
                p.ravel(), d.ravel(), md.ravel(), mp.ravel(),
                alpha=alpha, beta=beta, gamma=gamma,
            )
            new_phi.append(np_.reshape(p.shape))
            new_delta.append(nd_.reshape(p.shape))
    else:
        fn = dispatch("noloco_update", KernelConfig("jnp"))
        pairs = [
            fn(p, d, md, mp, alpha=alpha, beta=beta, gamma=gamma)
            for p, d, md, mp in zip(flat_phi, dms, mds, mps)
        ]
        new_phi = [a for a, _ in pairs]
        new_delta = [b for _, b in pairs]
    return (
        jax.tree.unflatten(treedef, new_phi),
        jax.tree.unflatten(treedef, new_delta),
    )


# ---------------------------------------------------------------------------
# Serving decode ops (inference-only: no vjp — they sit outside jax.grad)
# ---------------------------------------------------------------------------


def paged_attention(
    q: jax.Array,             # (R, H, D) one decode token per request slot
    k_pages: jax.Array,       # (NP, BS, KV, D) page pool
    v_pages: jax.Array,       # (NP, BS, KV, D)
    block_tables: jax.Array,  # (R, MB) int32 page ids per slot
    positions: jax.Array,     # (R,) int32 current token position per slot
    *,
    mode: str = "causal",
    window: int = 0,
    config: KernelConfig | None = None,
) -> jax.Array:
    """Paged decode attention: K/V gathered through per-slot block tables.

    Masking is positional (key j valid iff j <= positions[r], plus the
    sliding window for local layers), so trash-page writes and unallocated
    table entries never contribute.  The Pallas kernel requires H % KV == 0
    (GQA folding); ragged head counts route to the jnp twin."""
    impl, interpret = _resolve(config)
    h, kvh = q.shape[1], k_pages.shape[2]
    if impl == "pallas" and h % kvh == 0:
        return dispatch("paged_attention", KernelConfig("pallas", interpret))(
            q, k_pages, v_pages, block_tables, positions, mode=mode, window=window
        )
    return dispatch("paged_attention", KernelConfig("jnp"))(
        q, k_pages, v_pages, block_tables, positions, mode=mode, window=window
    )


def paged_chunk_attention(
    q: jax.Array,             # (R, C, H, D) one prefill chunk per request slot
    k_pages: jax.Array,       # (NP, BS, KV, D) page pool
    v_pages: jax.Array,       # (NP, BS, KV, D)
    block_tables: jax.Array,  # (R, MB) int32 page ids per slot
    positions: jax.Array,     # (R,) int32 base position of chunk token 0
    *,
    mode: str = "causal",
    window: int = 0,
    config: KernelConfig | None = None,
) -> jax.Array:
    """Chunked paged prefill attention: C query tokens per slot against the
    paged KV pool, chunk token c querying at ``positions[r] + c``.

    Ragged last chunks are handled upstream: tokens past a slot's valid
    length scatter to the trash page and their output rows are discarded, so
    ONE fixed-C program covers every prompt-length mix.  The Pallas kernel
    requires H % KV == 0; ragged head counts route to the jnp twin."""
    impl, interpret = _resolve(config)
    h, kvh = q.shape[2], k_pages.shape[2]
    if impl == "pallas" and h % kvh == 0:
        return dispatch("paged_chunk_attention", KernelConfig("pallas", interpret))(
            q, k_pages, v_pages, block_tables, positions, mode=mode, window=window
        )
    return dispatch("paged_chunk_attention", KernelConfig("jnp"))(
        q, k_pages, v_pages, block_tables, positions, mode=mode, window=window
    )


def rglru_decode(
    h: jax.Array,   # (R, W) recurrent state
    a: jax.Array,   # (R, W) per-token decay
    b: jax.Array,   # (R, W) per-token input
    *,
    config: KernelConfig | None = None,
) -> jax.Array:
    """Single RG-LRU decode step h' = a·h + b across request slots (f32)."""
    impl, interpret = _resolve(config)
    if impl == "pallas":
        return dispatch("rglru_decode", KernelConfig("pallas", interpret))(h, a, b)
    return dispatch("rglru_decode", KernelConfig("jnp"))(h, a, b)


def ssd_decode(
    state: jax.Array,   # (R, H, P, N) f32 recurrent state
    dt1: jax.Array,     # (R, H) positive step sizes for this token
    a: jax.Array,       # (H,) negative decay rates
    b1: jax.Array,      # (R, N) input projection for this token
    c1: jax.Array,      # (R, N) output projection for this token
    x1: jax.Array,      # (R, H, P) conv+silu'd input for this token
    *,
    config: KernelConfig | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Single SSD decode step at model layout; returns (state', y).

    state' = exp(dt·a)·state + dt·(x ⊗ B),  y = state'·C — the per-head
    decay/input are broadcast to channel granularity (H·P) here so the
    dispatched kernel is a pure fused elementwise + contraction over slots."""
    impl, interpret = _resolve(config)
    r, h, p, n = state.shape
    decay = jnp.repeat(jnp.exp(dt1.astype(jnp.float32) * a[None, :]), p, axis=1)
    dtx = (dt1.astype(jnp.float32)[..., None] * x1.astype(jnp.float32)).reshape(r, h * p)
    flat = state.reshape(r, h * p, n)
    if impl == "pallas":
        st, y = dispatch("ssd_decode", KernelConfig("pallas", interpret))(
            flat, decay, dtx, b1, c1
        )
    else:
        st, y = dispatch("ssd_decode", KernelConfig("jnp"))(flat, decay, dtx, b1, c1)
    return st.reshape(r, h, p, n), y.reshape(r, h, p)


# ---------------------------------------------------------------------------
# int8 wire codec kernels (consumed by comm/compress.py)
# ---------------------------------------------------------------------------


def int8_quantize(x: jax.Array, *, config: KernelConfig | None = None):
    """(NC, CHUNK) f32 → (q uint8, scale f32 (NC,), lo f32 (NC,))."""
    impl, interpret = _resolve(config)
    if impl == "pallas":
        return dispatch("int8_quantize", KernelConfig("pallas", interpret))(x)
    return dispatch("int8_quantize", KernelConfig("jnp"))(x)


def int8_dequantize(
    q: jax.Array, scale: jax.Array, lo: jax.Array,
    *, config: KernelConfig | None = None,
):
    """Inverse of :func:`int8_quantize` → (NC, CHUNK) f32."""
    impl, interpret = _resolve(config)
    if impl == "pallas":
        return dispatch("int8_dequantize", KernelConfig("pallas", interpret))(q, scale, lo)
    return dispatch("int8_dequantize", KernelConfig("jnp"))(q, scale, lo)
