"""Pallas TPU single-token recurrent-state updates for the serving hot loop.

Decode advances RG-LRU and SSD (Mamba-2) layers one token at a time, so the
training scan kernels (rglru_scan.py's log-step doubling, ssd_chunk.py's
chunked matmuls) degenerate to a single fused elementwise/contraction step.
These kernels keep that step on-chip — state in, state out, no HBM round
trips between the gate math and the output contraction — and exist mostly so
the serving engine exercises the same dispatch machinery (impl=auto|pallas|
jnp, interpret parity tests) as every training op.

Shapes are the serving-engine slot layout (R = request slots):

  rglru:  h, a, b                  (R, W)       → h' = a·h + b       (R, W) f32
  ssd:    state (R, HP, N) f32, decay/dtx (R, HP), b/c (R, N)
          → state' = decay·state + dtx ⊗ b,  y = Σ_n state'·c   ((R,HP,N), (R,HP))

Both compute in f32 (the recurrent state is f32-resident in the engine) and
tile the trailing dims at lane width.  Validated on CPU with interpret=True
against the jnp twins in ref.py; the TPU is the TARGET.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tpu_compat import CompilerParams

BLOCK_W = 128   # lane-aligned width tile


def _rglru_kernel(h_ref, a_ref, b_ref, o_ref):
    h = h_ref[...].astype(jnp.float32)
    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    o_ref[...] = a * h + b


@functools.partial(jax.jit, static_argnames=("block_w", "interpret"))
def pallas_rglru_decode(
    h: jax.Array,   # (R, W) recurrent state
    a: jax.Array,   # (R, W) per-token decay
    b: jax.Array,   # (R, W) per-token input
    *,
    block_w: int = BLOCK_W,
    interpret: bool = True,
) -> jax.Array:
    """One RG-LRU step h' = a·h + b across all request slots; returns f32."""
    r, w = h.shape
    pw = (-w) % block_w
    if pw:
        h = jnp.pad(h, ((0, 0), (0, pw)))
        a = jnp.pad(a, ((0, 0), (0, pw)))
        b = jnp.pad(b, ((0, 0), (0, pw)))
    wp = w + pw
    grid = (wp // block_w,)
    spec = pl.BlockSpec((r, block_w), lambda wi: (0, wi))
    out = pl.pallas_call(
        _rglru_kernel,
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((r, wp), jnp.float32),
        compiler_params=CompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(h, a, b)
    return out[:, :w]


def _ssd_kernel(state_ref, decay_ref, dtx_ref, b_ref, c_ref, st_ref, y_ref):
    st = state_ref[0].astype(jnp.float32)        # (HP, N)
    decay = decay_ref[0].astype(jnp.float32)     # (HP,)
    dtx = dtx_ref[0].astype(jnp.float32)
    b = b_ref[0].astype(jnp.float32)             # (N,)
    c = c_ref[0].astype(jnp.float32)
    new = st * decay[:, None] + dtx[:, None] * b[None, :]
    st_ref[0] = new
    y_ref[0] = new @ c


@functools.partial(jax.jit, static_argnames=("interpret",))
def pallas_ssd_decode(
    state: jax.Array,   # (R, HP, N) f32 recurrent state (HP = heads·headdim)
    decay: jax.Array,   # (R, HP) exp(dt·A) per channel
    dtx: jax.Array,     # (R, HP) dt·x per channel
    b: jax.Array,       # (R, N) input projection
    c: jax.Array,       # (R, N) output projection
    *,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """One SSD step per slot: state' = decay·state + dtx⊗b, y = state'·c."""
    r, hp, n = state.shape
    out = pl.pallas_call(
        _ssd_kernel,
        grid=(r,),
        in_specs=[
            pl.BlockSpec((1, hp, n), lambda ri: (ri, 0, 0)),
            pl.BlockSpec((1, hp), lambda ri: (ri, 0)),
            pl.BlockSpec((1, hp), lambda ri: (ri, 0)),
            pl.BlockSpec((1, n), lambda ri: (ri, 0)),
            pl.BlockSpec((1, n), lambda ri: (ri, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, hp, n), lambda ri: (ri, 0, 0)),
            pl.BlockSpec((1, hp), lambda ri: (ri, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, hp, n), jnp.float32),
            jax.ShapeDtypeStruct((r, hp), jnp.float32),
        ],
        compiler_params=CompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(state, decay, dtx, b, c)
    return out[0], out[1]
