"""msgpack pytree checkpointing (orbax isn't on this box).

Layout: one directory per step with
    manifest.msgpack   — treedef (as nested lists/dicts), shapes, dtypes
    arrays.msgpack     — leaf buffers (raw bytes, row-major)

Supports per-replica saves (NoLoCo's weights are an ENSEMBLE — each replica's
φ/θ/δ are distinct): pass the stacked trees and every leaf's leading replica
dim is preserved.  Restore is exact (bit-identical round trip, tested).
"""

from __future__ import annotations

import os
from typing import Any

import msgpack
import numpy as np

import jax
import jax.numpy as jnp

__all__ = ["save", "restore", "latest_step"]

_SENTINEL = "__leaf__"


def _encode_tree(tree: Any, leaves: list) -> Any:
    if isinstance(tree, dict):
        return {str(k): _encode_tree(v, leaves) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return {
            "__seq__": type(tree).__name__,
            "items": [_encode_tree(v, leaves) for v in tree],
        }
    if tree is None:
        return {"__none__": True}
    arr = np.asarray(jax.device_get(tree))
    idx = len(leaves)
    leaves.append(arr)
    return {_SENTINEL: idx, "dtype": str(arr.dtype), "shape": list(arr.shape)}


def _decode_tree(node: Any, leaves: list):
    if isinstance(node, dict):
        if _SENTINEL in node:
            arr = leaves[node[_SENTINEL]]
            return jnp.asarray(arr)
        if node.get("__none__"):
            return None
        if "__seq__" in node:
            items = [_decode_tree(v, leaves) for v in node["items"]]
            return tuple(items) if node["__seq__"] == "tuple" else items
        return {k: _decode_tree(v, leaves) for k, v in node.items()}
    raise ValueError(f"bad manifest node: {node!r}")


def save(path: str, step: int, tree: Any, *, keep: int | None = None) -> str:
    """Serialize a pytree of arrays (dataclass states should be passed as
    dicts via dataclasses.asdict-style conversion by the caller).

    The write is ATOMIC at the directory level: contents go into a
    ``step_XXXXXXXX.tmp`` staging directory that is renamed into place only
    once both files are fully written.  A run killed mid-save (the elastic
    story's normal failure mode — node churn) can therefore never leave a
    half-written latest checkpoint for ``--resume`` to pick up;
    :func:`latest_step` ignores staging directories by construction.

    ``keep``: retain only the newest ``keep`` step directories (incl. this
    one) — bounds disk use under the engine's periodic checkpointing."""
    import shutil

    d = os.path.join(path, f"step_{step:08d}")
    tmp = d + ".tmp"
    # sweep staging leftovers from runs killed mid-save (any step, not just
    # this one) so crashes can't accumulate unpruned disk use
    if os.path.isdir(path):
        for name in os.listdir(path):
            if name.endswith(".tmp"):
                shutil.rmtree(os.path.join(path, name), ignore_errors=True)
    os.makedirs(tmp, exist_ok=True)
    leaves: list[np.ndarray] = []
    manifest = _encode_tree(tree, leaves)
    with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))
    blobs = []
    for arr in leaves:
        a = np.ascontiguousarray(arr)  # NB: promotes 0-d to 1-d; keep arr.shape
        # bfloat16 has no numpy dtype string msgpack knows; ship raw bytes
        blobs.append({"dtype": str(a.dtype), "shape": list(arr.shape), "data": a.tobytes()})
    with open(os.path.join(tmp, "arrays.msgpack"), "wb") as f:
        f.write(msgpack.packb(blobs))
    shutil.rmtree(d, ignore_errors=True)  # re-saving the same step overwrites
    os.rename(tmp, d)
    if keep is not None and keep > 0:
        import re

        found = sorted(
            (int(m.group(1)), n)
            for n in os.listdir(path)
            for m in [re.fullmatch(r"step_(\d+)", n)]
            if m
        )
        for _, name in found[:-keep]:
            shutil.rmtree(os.path.join(path, name), ignore_errors=True)
    return d


def restore(path: str, step: int | None = None) -> Any:
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {path}")
    d = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read(), strict_map_key=False)
    with open(os.path.join(d, "arrays.msgpack"), "rb") as f:
        blobs = msgpack.unpackb(f.read(), strict_map_key=False)
    import ml_dtypes  # ships with jax; provides numpy bfloat16 etc.

    leaves = []
    for b in blobs:
        dt = b["dtype"]
        np_dtype = (
            np.dtype(getattr(ml_dtypes, dt)) if hasattr(ml_dtypes, dt) else np.dtype(dt)
        )
        leaves.append(np.frombuffer(b["data"], dtype=np_dtype).reshape(b["shape"]))
    return _decode_tree(manifest, leaves)


def latest_step(path: str) -> int | None:
    if not os.path.isdir(path):
        return None
    import re

    steps = [
        int(m.group(1))
        for n in os.listdir(path)
        for m in [re.fullmatch(r"step_(\d+)", n)]
        if m
    ]
    return max(steps) if steps else None
