"""Inspect the compiled HLO of the NoLoCo vs DiLoCo outer step on an 8-device
host mesh: NoLoCo lowers to collective-permute ONLY; DiLoCo to all-reduce.
This is the paper's central systems claim, visible in the IR.

    python examples/gossip_vs_allreduce_hlo.py   (sets its own XLA_FLAGS)
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.core import pairing
from repro.core.outer import OuterConfig
from repro.parallel import compat
from repro.launch import roofline as rf
from repro.launch.mesh import make_test_mesh
from repro.models import model as M
from repro.models.common import unzip
from repro.models.config import ModelConfig
from repro.parallel import plans as PL, steps as ST


def main() -> None:
    mesh = make_test_mesh(4, 2)
    cfg = ModelConfig(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                      d_ff=128, vocab_size=256, dtype="float32", remat=False)
    plan = PL.make_plan("gossip_dp", mesh)
    stacked = ST.stack_replicas(M.init_params(jax.random.PRNGKey(0), cfg), plan.replicas)
    vals, _ = unzip(stacked)
    theta_abs = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), vals)
    pspecs = PL.param_pspecs(plan, mesh, stacked)
    perm = pairing.ppermute_pairs(0, plan.replicas)
    rep = jax.ShapeDtypeStruct((plan.replicas,), jnp.int32)

    with compat.set_mesh(mesh):
        for method in ("noloco", "diloco"):
            ocfg = OuterConfig(method=method, alpha=0.5 if method == "noloco" else 0.3)
            fn = ST.build_outer_step(plan, mesh, pspecs, ocfg, perm)
            hlo = fn.lower(theta_abs, theta_abs, theta_abs, rep).compile().as_text()
            stats = rf.collective_bytes(hlo, model_size=2)
            print(f"{method:8s} collectives: {stats.counts}  "
                  f"bytes={stats.total_bytes:,}")


if __name__ == "__main__":
    main()
