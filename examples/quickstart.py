"""Quickstart: train a tiny LM with NoLoCo on 4 simulated replicas, watch the
loss fall and the replica ensemble converge.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import run_training
from repro.models.config import ModelConfig


def main() -> None:
    cfg = ModelConfig(
        name="quickstart-lm",
        num_layers=2, d_model=96, num_heads=4, num_kv_heads=2,
        d_ff=192, vocab_size=256, dtype="float32", remat=False,
    )
    res = run_training(
        cfg, method="noloco", replicas=4, per_replica_batch=2, seq_len=64,
        steps=60, inner_lr=2e-3, inner_steps=15, eval_every=15, log=True,
    )
    print(f"\nfinal train loss {res['losses'][-1]:.3f} "
          f"(started {res['losses'][0]:.3f}); "
          f"ensemble weight std {res['final_weight_std']:.5f}")
    assert res["losses"][-1] < res["losses"][0]


if __name__ == "__main__":
    main()
