"""End-to-end driver: the paper's core comparison (NoLoCo vs DiLoCo vs FSDP)
on the paper's OWN small architecture (reduced width for CPU), a few hundred
steps, with the paper's hyper-parameters (α, β, m from §4 scaled down).

    PYTHONPATH=src python examples/train_noloco_vs_diloco.py [--steps 200]
"""
import argparse
import json
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import registry
from repro.launch.train import run_training


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--replicas", type=int, default=8)
    args = ap.parse_args()

    cfg = registry.get_config("paper-small-125m").reduced(
        vocab_size=512, dtype="float32", remat=False
    )
    out = {}
    for method in ("fsdp", "diloco", "noloco"):
        res = run_training(
            cfg, method=method, replicas=args.replicas, per_replica_batch=2,
            seq_len=128, steps=args.steps, inner_lr=2e-3,
            inner_steps=20 if method == "noloco" else 40,  # NoLoCo syncs 2x as often (paper §4)
            eval_every=max(args.steps // 4, 1), log=True,
        )
        out[method] = {
            "final_eval": res["evals"][-1][1],
            "weight_std": res["final_weight_std"],
        }
        print(f"== {method}: {out[method]}")
    rel = (out["diloco"]["final_eval"] - out["noloco"]["final_eval"]) / out["fsdp"]["final_eval"]
    out["rel_ppl_diff_eq4"] = rel
    print(json.dumps(out, indent=2))


if __name__ == "__main__":
    main()
