"""Continuous-batching serving example: mixed-length requests through the
paged-KV engine — the ensemble angle: each NoLoCo replica can serve its own
requests (here: one replica = one model).

    PYTHONPATH=src python examples/serve_decode.py
    # serve a trained checkpoint with explicit kernel impl:
    PYTHONPATH=src python examples/serve_decode.py --ckpt /tmp/run_ck --impl jnp
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.kernels.dispatch import KernelConfig
from repro.models import model as M
from repro.models.common import values_of
from repro.models.config import ModelConfig
from repro.serve import Request, ServeConfig, ServeEngine, promote


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ckpt", default=None, help="promote a training checkpoint")
    ap.add_argument("--replica", type=int, default=0)
    ap.add_argument("--impl", default="auto", choices=["auto", "pallas", "jnp"])
    args = ap.parse_args()

    kcfg = KernelConfig(impl=args.impl)
    cfg = ModelConfig(
        name="serve-demo", num_layers=2, d_model=96, num_heads=4,
        num_kv_heads=2, d_ff=192, vocab_size=256, dtype="float32", remat=False,
        kernels=kcfg,
    )
    if args.ckpt:
        params, info = promote(args.ckpt, replica=args.replica)
        print("promoted:", info)
    else:
        params = values_of(M.init_params(jax.random.PRNGKey(0), cfg))

    # Mixed prompt/generation lengths: short requests finish early and their
    # slots are refilled from the queue while long ones keep decoding.
    key = jax.random.PRNGKey(1)
    requests = []
    for rid, (plen, glen) in enumerate([(12, 20), (4, 6), (24, 12), (7, 20), (3, 9)]):
        key, sub = jax.random.split(key)
        prompt = jax.random.randint(sub, (plen,), 0, cfg.vocab_size)
        requests.append(Request(rid=rid, prompt=[int(t) for t in prompt], max_new=glen))

    scfg = ServeConfig(max_slots=3, num_pages=64, page_size=8, max_new_cap=20)
    engine = ServeEngine(params, cfg, scfg)
    finished = engine.run(requests)

    for f in sorted(finished, key=lambda f: f.rid):
        print(f"request {f.rid}: prompt_len={len(f.prompt)} -> {f.tokens}")
    total = sum(len(f.tokens) for f in finished)
    assert len(finished) == len(requests)
    assert all(len(f.tokens) == r.max_new for f, r in
               zip(sorted(finished, key=lambda f: f.rid), requests))
    print(f"OK: served {len(finished)} requests ({total} tokens) through "
          f"{scfg.max_slots} slots in {engine.decode_steps} decode steps")


if __name__ == "__main__":
    main()
