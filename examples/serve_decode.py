"""Batched serving example: prefill a prompt batch, then greedy-decode new
tokens with the KV cache — the ensemble angle: each NoLoCo replica can serve
its own requests (here: one replica = one model).

    PYTHONPATH=src python examples/serve_decode.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.common import values_of
from repro.models.config import ModelConfig
from repro.parallel.sharding import ShardCtx


def main() -> None:
    cfg = ModelConfig(
        name="serve-demo", num_layers=2, d_model=96, num_heads=4,
        num_kv_heads=2, d_ff=192, vocab_size=256, dtype="float32", remat=False,
    )
    ctx = ShardCtx.local()
    params = values_of(M.init_params(jax.random.PRNGKey(0), cfg))

    batch, prompt_len, gen_len, max_len = 4, 12, 20, 64
    prompts = jax.random.randint(jax.random.PRNGKey(1), (batch, prompt_len), 0, 256)

    caches = values_of(M.init_cache_tree(cfg, batch, max_len))
    _, caches = M.prefill(params, cfg, {"tokens": prompts}, caches, ctx)
    decode = jax.jit(lambda p, t, i, c: M.decode_step(p, cfg, t, i, c, ctx))

    tok = prompts[:, -1:]
    outs = []
    for i in range(gen_len):
        logits, caches = decode(params, tok, jnp.asarray(prompt_len + i), caches)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        outs.append(tok)
    gen = jnp.concatenate(outs, axis=1)
    print("prompts:\n", prompts)
    print("generation:\n", gen)
    assert gen.shape == (batch, gen_len)
    print("OK: batched prefill+decode served", batch, "requests")


if __name__ == "__main__":
    main()
